"""Background streaming telemetry exporter for production-rate serving.

``launch/serve.py``-style runs previously exposed metrics exactly once, at
the end of ``generate()`` -- useless for a serve that runs for minutes.
:class:`StreamingExporter` is a daemon thread that, every ``interval_s``:

  1. invokes the registered *collectors* (engines register one for the
     duration of ``generate()`` so pool/mapper gauges update on the
     streaming cadence, not just at the end);
  2. appends one complete JSON line to ``metrics.jsonl`` (each line is a
     self-contained snapshot: a scrape that reads a prefix of the file
     sees only whole snapshots -- the line is written and flushed in one
     call);
  3. rewrites ``metrics.prom`` (Prometheus textfile-collector format)
     atomically: write to a temp file in the same directory, then
     ``os.replace`` -- a concurrent reader never observes a torn file.

Lifecycle is module-level (one exporter per process, like the metrics
registry): ``start(out_dir)`` / ``stop()`` / ``active()``.  ``stop()``
performs a final flush, so short runs still get at least one snapshot.
Collector callbacks are exception-isolated: a failing collector is
dropped from that flush, never kills the exporter thread.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Callable

from repro.obs import metrics, optrace

DEFAULT_INTERVAL_S = 10.0

JSONL_NAME = "metrics.jsonl"
PROM_NAME = "metrics.prom"


class StreamingExporter:
    """Periodic atomic snapshot writer (JSONL + Prometheus textfile)."""

    def __init__(self, out_dir: str, *,
                 interval_s: float = DEFAULT_INTERVAL_S):
        if interval_s <= 0:
            raise ValueError(f"interval_s must be > 0, got {interval_s}")
        self.out_dir = out_dir
        self.interval_s = float(interval_s)
        self.jsonl_path = os.path.join(out_dir, JSONL_NAME)
        self.prom_path = os.path.join(out_dir, PROM_NAME)
        self.snapshots_written = 0
        self._collectors: list[Callable[[], None]] = []
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------ lifecycle

    def start(self) -> "StreamingExporter":
        os.makedirs(self.out_dir, exist_ok=True)
        # truncate any previous run's stream so seq numbers stay monotone
        open(self.jsonl_path, "w").close()
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._run, name="obs-streaming", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        t = self._thread
        if t is not None:
            t.join(timeout=max(5.0, 2 * self.interval_s))
            self._thread = None
        self.flush()                           # final snapshot on the way out

    def running(self) -> bool:
        t = self._thread
        return t is not None and t.is_alive()

    def _run(self) -> None:
        while not self._stop.wait(self.interval_s):
            self.flush()

    # ------------------------------------------------------------ collectors

    def add_collector(self, fn: Callable[[], None]) -> None:
        """Register a callback run before each snapshot (engines publish
        their pool/mapper gauges here)."""
        with self._lock:
            if fn not in self._collectors:
                self._collectors.append(fn)

    def remove_collector(self, fn: Callable[[], None]) -> None:
        with self._lock:
            if fn in self._collectors:
                self._collectors.remove(fn)

    # ------------------------------------------------------------ snapshots

    def flush(self) -> int:
        """Collect, then write one JSONL snapshot and rewrite the prom
        textfile atomically.  Returns the snapshot sequence number."""
        with self._lock:
            collectors = list(self._collectors)
        for fn in collectors:
            try:
                fn()
            except Exception:
                pass                           # never kill the exporter
        with self._lock:
            self.snapshots_written += 1
            seq = self.snapshots_written
            line = json.dumps({
                "seq": seq,
                "ts_unix_s": time.time(),
                "uptime_s": round(optrace.now_s(), 6),
                "dropped_ops": optrace.dropped_ops(),
                "sampled_out_ops": optrace.sampled_out_ops(),
                "metrics": metrics.snapshot(),
            }, sort_keys=True)
            with open(self.jsonl_path, "a") as f:
                f.write(line + "\n")
                f.flush()
            tmp = self.prom_path + ".tmp"
            with open(tmp, "w") as f:
                f.write(metrics.prometheus_text())
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self.prom_path)
        return seq


# ---------------------------------------------------------------------------
# module-level singleton (one exporter per process)
# ---------------------------------------------------------------------------

_EXPORTER: StreamingExporter | None = None


def start(out_dir: str, *,
          interval_s: float = DEFAULT_INTERVAL_S) -> StreamingExporter:
    """Start the process streaming exporter (stops any previous one)."""
    global _EXPORTER
    if _EXPORTER is not None:
        _EXPORTER.stop()
    _EXPORTER = StreamingExporter(out_dir, interval_s=interval_s).start()
    return _EXPORTER


def stop() -> None:
    global _EXPORTER
    if _EXPORTER is not None:
        _EXPORTER.stop()
        _EXPORTER = None


def active() -> StreamingExporter | None:
    """The running exporter, or None (engines use this to decide whether
    to register their per-run collector)."""
    if _EXPORTER is not None and _EXPORTER.running():
        return _EXPORTER
    return None


def add_collector(fn: Callable[[], None]) -> bool:
    """Register ``fn`` on the running exporter; False if none is active."""
    exp = active()
    if exp is None:
        return False
    exp.add_collector(fn)
    return True


def remove_collector(fn: Callable[[], None]) -> None:
    exp = _EXPORTER
    if exp is not None:
        exp.remove_collector(fn)


def read_jsonl(path: str) -> list[dict[str, Any]]:
    """Parse a streamed ``metrics.jsonl`` (complete lines only -- a
    trailing partial line from a crashed writer is ignored)."""
    out: list[dict[str, Any]] = []
    with open(path) as f:
        for line in f:
            if not line.endswith("\n"):
                break
            out.append(json.loads(line))
    return out
