"""Jit-safe device-timeline annotation.

The optrace/metrics layer is host-only by construction: the tracer guard
drops any record made under a jit trace, and LNT009 bans host clocks from
traced step functions.  That leaves the jitted step *interior* -- where all
production time is spent -- opaque.  The sanctioned way to label it is the
name stack: ``jax.named_scope`` pushes a scope name at trace time, the
staged ops carry it into HLO metadata, and ``jax.profiler`` device traces
render those names as nested tracks -- so "attention" / "moe" / "axon:gemm"
show up on the device timeline under the same Perfetto view as the host
serve spans.

Two primitives:

  * :func:`scope` -- legal anywhere.  Under a trace it only pushes the
    name stack (zero runtime cost; the label is baked into the lowered
    HLO).  On the host, while a ``jax.profiler`` capture is running, it
    additionally enters a ``jax.profiler.TraceAnnotation`` so eager
    sections line up on the profiler's host track.
  * :func:`host_scope` -- host-only ``TraceAnnotation`` (no name-stack
    entry), for engine loops that want their step dispatch visible on the
    profiler timeline; gate with ``enabled=`` so telemetry-off runs skip
    even the capture check.

The TraceAnnotation (a TraceMe) is only entered while a profiler capture
is active: it has no consumer otherwise, and entering one per engine step
or per eager dispatch is measurable overhead on sub-millisecond steps.

Labels must be static strings (a plain literal or a host-computed name
such as ``"axon:" + kind``).  Interpolating a *traced* value into a label
(f-string / ``str.format`` on tracers) either crashes at trace time or
bakes one trace's repr into every subsequent step -- lint rule LNT010
rejects both forms inside traced code.
"""
from __future__ import annotations

import contextlib

import jax

__all__ = ["scope", "host_scope"]


def _capturing() -> bool:
    """True while a ``jax.profiler`` capture is running (repro.obs.profiler
    tracks it).  Imported lazily: profiler imports this module at top."""
    from repro.obs import profiler
    return profiler.active()


@contextlib.contextmanager
def scope(name: str):
    """Label everything staged (or run) inside the block with ``name``."""
    if jax.core.trace_state_clean() and _capturing():
        with jax.named_scope(name), jax.profiler.TraceAnnotation(name):
            yield
    else:
        with jax.named_scope(name):
            yield


@contextlib.contextmanager
def host_scope(name: str, *, enabled: bool = True):
    """Host-side profiler annotation only (no name-stack entry).

    A no-op when ``enabled`` is falsy, when no profiler capture is
    running, or when called under a trace -- an engine can wrap its step
    dispatch unconditionally and stay a true no-op with telemetry off.
    """
    if enabled and jax.core.trace_state_clean() and _capturing():
        with jax.profiler.TraceAnnotation(name):
            yield
    else:
        yield
