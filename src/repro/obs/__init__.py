"""repro.obs -- runtime telemetry: metrics registry, dispatch op tracing,
Chrome-trace export, and opt-in profiler hooks.

Everything is off by default.  ``obs.enable()`` flips on the op-trace ring
and span recording; the metrics registry is always importable but only
ever mutated from instrumented call sites that first check
``optrace.enabled()`` -- so with telemetry off, the hot loops perform one
module-attribute read and no allocation.

Quick start::

    import repro.obs as obs

    obs.enable()
    ... run a workload ...
    obs.write_chrome_trace("trace.json")    # load in ui.perfetto.dev
    obs.metrics.REGISTRY.write_json("metrics.json")
    print(obs.metrics.prometheus_text())

Or from the shell::

    python -m repro.obs --smoke --trace-out trace.json \
        --metrics-out metrics.json
"""
from repro.obs import (annotate, attribution, metrics, optrace, profiler,
                       streaming, trace_export)
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               REGISTRY, host_clean)
from repro.obs.optrace import (OpEvent, SpanEvent, configure, disable,
                               enable, enabled, record_dispatch, span)
from repro.obs.trace_export import (chrome_trace, validate_chrome_trace,
                                    write_chrome_trace)

__all__ = [
    "annotate", "attribution", "metrics", "optrace", "profiler",
    "streaming", "trace_export",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY",
    "host_clean",
    "OpEvent", "SpanEvent", "configure", "disable", "enable", "enabled",
    "record_dispatch", "span",
    "chrome_trace", "validate_chrome_trace", "write_chrome_trace",
]
