"""Dispatch-level op tracing and host-side span recording.

Off by default and a true no-op while disabled: the dispatcher and the
engines guard every record behind :func:`enabled` (one attribute read), so
the hot loop allocates nothing and touches no metric objects until
:func:`enable` flips the switch.

When enabled, two bounded ring buffers fill up:

  * **op events** -- one :class:`OpEvent` per ``axon.einsum`` / ``matmul``
    / ``conv2d`` / ``depthwise_conv2d`` dispatch *executed on the host*
    (kind, operand shapes/dtypes, chosen backend, mapper blocking and
    cache hit/miss, quant route and fallback reason, modeled
    FLOPs/bytes/energy from ``repro.core``).  Dispatches issued while JAX
    is staging a trace (``jax.jit``, ``jax.eval_shape``) are NOT recorded:
    a jitted engine step dispatches once per compilation, not per
    execution, and counting those as "ops" would be a lie.  Run the
    workload eagerly (the ``python -m repro.obs`` CLI does) to observe the
    dispatch stream.
  * **spans** -- host wall-time slices (engine steps, per-request serve
    phases, profiler scopes) that export as Chrome-trace/Perfetto ``X``
    slices via ``repro.obs.trace_export``.

Recording also feeds the process metrics registry (``repro.obs.metrics``):
``axon_dispatch_total{op,kind}``, ``axon_fallback_total{op,reason}``, and
``axon_quant_route_total{route,reason}``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from collections import deque
from typing import Any, Iterator

from repro.obs import metrics

DEFAULT_RING_SIZE = 4096

# Chrome-trace thread-id layout: ops and engine steps on low tids, per-
# request rows offset so they render as their own lanes under the process.
TID_OPS = 1
TID_STEPS = 2
TID_REQUEST_BASE = 1000


@dataclasses.dataclass(frozen=True)
class OpEvent:
    """One host-visible dispatch decision."""

    ts_s: float                       # seconds since enable()
    op: str                           # einsum | matmul | conv2d | depthwise
    kind: str                         # registry kind, or "xla"
    spec: str | None = None
    lhs: tuple[int, ...] | None = None
    rhs: tuple[int, ...] | None = None
    dtype: str | None = None
    backend: str | None = None        # resolved policy backend
    block: tuple[int, ...] | None = None
    order: str | None = None          # mapper loop order (OS/WS/IS)
    mapper_hit: bool | None = None    # blocking decision already cached?
    route: str | None = None          # quant_route() route, if quantized
    reason: str | None = None         # fallback / routing reason
    flops: float = 0.0                # modeled MACs*2
    bytes: float = 0.0                # modeled HBM operand traffic
    energy_j: float = 0.0             # modeled DRAM energy

    def args(self) -> dict[str, Any]:
        """Chrome-trace ``args`` payload (drop Nones, keep it JSON-clean)."""
        d = dataclasses.asdict(self)
        d.pop("ts_s")
        return {k: (list(v) if isinstance(v, tuple) else v)
                for k, v in d.items() if v is not None}


@dataclasses.dataclass(frozen=True)
class SpanEvent:
    """One host wall-time slice (Chrome-trace ``X``) or instant (``i``)."""

    name: str
    ts_s: float                       # seconds since enable()
    dur_s: float                      # 0.0 => instant event
    cat: str = "engine"
    tid: int = TID_STEPS
    args: dict[str, Any] = dataclasses.field(default_factory=dict)
    instant: bool = False


class _State:
    __slots__ = ("enabled", "ring", "spans", "t0", "dropped_ops",
                 "sample_every", "op_seq", "sampled_out",
                 "measure_dispatch", "traced")

    def __init__(self) -> None:
        self.enabled = False
        self.ring: deque[OpEvent] = deque(maxlen=DEFAULT_RING_SIZE)
        self.spans: deque[SpanEvent] = deque(maxlen=DEFAULT_RING_SIZE)
        self.t0 = time.perf_counter()
        self.dropped_ops = 0
        # recording configuration (sticky across enable()/disable())
        self.sample_every = 1
        self.measure_dispatch = False
        # sampling + traced-cost bookkeeping (reset with the rings)
        self.op_seq = 0
        self.sampled_out = 0
        self.traced: dict[tuple[str, str], list[float]] = {}


_STATE = _State()


def enable(ring_size: int = DEFAULT_RING_SIZE, *, reset: bool = True) -> None:
    """Turn op tracing on (rings bounded at ``ring_size`` events)."""
    if ring_size < 1:
        raise ValueError(f"ring_size must be >= 1, got {ring_size}")
    if reset or _STATE.ring.maxlen != ring_size:
        _STATE.ring = deque(maxlen=ring_size)
        _STATE.spans = deque(maxlen=ring_size)
        _STATE.t0 = time.perf_counter()
        _STATE.dropped_ops = 0
        _STATE.op_seq = 0
        _STATE.sampled_out = 0
        _STATE.traced = {}
    _STATE.enabled = True


def configure(*, sample_every: int | None = None,
              measure_dispatch: bool | None = None) -> dict[str, Any]:
    """Adjust recording behaviour; returns the active configuration.

    ``sample_every=N`` keeps every side counter exact but appends only
    every Nth eager dispatch to the op ring (the rest are tallied in
    :func:`sampled_out_ops`), so always-on tracing stays cheap at
    production dispatch rates.  ``measure_dispatch=True`` asks the
    dispatcher to time each eager kernel call through
    ``jax.block_until_ready`` and record a ``dispatch:<kind>`` wall scope
    -- the measured side of ``repro.obs.attribution`` -- at the cost of
    serializing dispatch, so leave it off for throughput runs.

    Configuration is sticky across :func:`enable`/:func:`disable`; pass
    explicit values to restore defaults (``sample_every=1``,
    ``measure_dispatch=False``).
    """
    if sample_every is not None:
        if sample_every < 1:
            raise ValueError(
                f"sample_every must be >= 1, got {sample_every}")
        _STATE.sample_every = int(sample_every)
    if measure_dispatch is not None:
        _STATE.measure_dispatch = bool(measure_dispatch)
    return {"sample_every": _STATE.sample_every,
            "measure_dispatch": _STATE.measure_dispatch}


def sample_every() -> int:
    return _STATE.sample_every


def measuring() -> bool:
    """True when enabled AND dispatch-wall measurement was requested."""
    return _STATE.enabled and _STATE.measure_dispatch


def disable() -> None:
    _STATE.enabled = False


def enabled() -> bool:
    return _STATE.enabled


def reset() -> None:
    """Drop buffered events (keeps the enabled flag and configuration)."""
    _STATE.ring.clear()
    _STATE.spans.clear()
    _STATE.t0 = time.perf_counter()
    _STATE.dropped_ops = 0
    _STATE.op_seq = 0
    _STATE.sampled_out = 0
    _STATE.traced = {}


def epoch() -> float:
    """``time.perf_counter()`` origin of all recorded timestamps."""
    return _STATE.t0


def now_s() -> float:
    return time.perf_counter() - _STATE.t0


def events() -> list[OpEvent]:
    return list(_STATE.ring)


def spans() -> list[SpanEvent]:
    return list(_STATE.spans)


def dropped_ops() -> int:
    """Op events evicted from the bounded ring so far."""
    return _STATE.dropped_ops


def sampled_out_ops() -> int:
    """Dispatches counted but skipped by ``configure(sample_every=N)``."""
    return _STATE.sampled_out


def traced_costs() -> dict[tuple[str, str], dict[str, float]]:
    """Modeled cost of dispatches staged *under a trace* while enabled.

    A jitted step dispatches once per compilation, so these are per-trace
    sums keyed by ``(op, kind)`` -- the modeled cost of one traced step
    body, not of any execution.  Engines difference :func:`traced_totals`
    around a jitted call to learn each step signature's modeled cost.
    """
    return {k: {"count": v[0], "flops": v[1], "bytes": v[2],
                "energy_j": v[3]}
            for k, v in _STATE.traced.items()}


def traced_totals() -> dict[str, float]:
    """Aggregate of :func:`traced_costs` across all (op, kind)."""
    tot = {"count": 0.0, "flops": 0.0, "bytes": 0.0, "energy_j": 0.0}
    for row in _STATE.traced.values():
        tot["count"] += row[0]
        tot["flops"] += row[1]
        tot["bytes"] += row[2]
        tot["energy_j"] += row[3]
    return tot


# ---------------------------------------------------------------------------
# op recording (called by repro.axon.dispatch)
# ---------------------------------------------------------------------------


def record_dispatch(op: str, kind: str, **fields: Any) -> None:
    """Record one dispatch decision (no-op when disabled; while JAX is
    staging a trace only the modeled-cost ledger is fed -- see the module
    docstring)."""
    if not _STATE.enabled:
        return
    if not metrics.host_clean():
        # Staged under a trace: this dispatch runs once per compilation,
        # not per execution, so no op event and no counters.  Its modeled
        # cost is still a host constant (shapes are static), so keep the
        # per-(op, kind) ledger that attribution uses to cost jitted steps.
        row = _STATE.traced.setdefault((op, kind), [0.0, 0.0, 0.0, 0.0])
        row[0] += 1.0
        row[1] += float(fields.get("flops") or 0.0)
        row[2] += float(fields.get("bytes") or 0.0)
        row[3] += float(fields.get("energy_j") or 0.0)
        return
    ev = OpEvent(ts_s=now_s(), op=op, kind=kind, **fields)
    # side counters first: they stay exact under sampling
    metrics.counter(
        "axon_dispatch_total", "dispatches by operator and kernel kind",
        labels=("op", "kind")).inc(op=op, kind=kind)
    if ev.reason is not None and kind in ("xla", "dequant"):
        metrics.counter(
            "axon_fallback_total", "XLA/dequant fallbacks by reason",
            labels=("op", "reason")).inc(op=op, reason=ev.reason)
    if ev.route is not None:
        metrics.counter(
            "axon_quant_route_total", "quant_route() outcomes",
            labels=("route", "reason")).inc(route=ev.route,
                                            reason=ev.reason or "")
    if ev.mapper_hit is not None:
        metrics.counter(
            "axon_mapper_lookups_total", "mapper blocking lookups",
            labels=("hit",)).inc(hit=str(bool(ev.mapper_hit)).lower())
    _STATE.op_seq += 1
    if _STATE.sample_every > 1 and _STATE.op_seq % _STATE.sample_every:
        _STATE.sampled_out += 1
        return
    if len(_STATE.ring) == _STATE.ring.maxlen:
        _STATE.dropped_ops += 1
    _STATE.ring.append(ev)


# ---------------------------------------------------------------------------
# span recording (engines, launch scripts, profiler scopes)
# ---------------------------------------------------------------------------


def add_span(name: str, t_start: float, dur_s: float, *, cat: str = "engine",
             tid: int = TID_STEPS, args: dict[str, Any] | None = None
             ) -> None:
    """Record a completed slice.  ``t_start`` is an absolute
    ``time.perf_counter()`` value (converted against :func:`epoch`)."""
    if not _STATE.enabled or not metrics.host_clean():
        return
    _STATE.spans.append(SpanEvent(
        name=name, ts_s=max(0.0, t_start - _STATE.t0),
        dur_s=max(0.0, dur_s), cat=cat, tid=tid, args=args or {}))


def add_instant(name: str, t_at: float | None = None, *, cat: str = "engine",
                tid: int = TID_STEPS, args: dict[str, Any] | None = None
                ) -> None:
    if not _STATE.enabled or not metrics.host_clean():
        return
    t = time.perf_counter() if t_at is None else t_at
    _STATE.spans.append(SpanEvent(
        name=name, ts_s=max(0.0, t - _STATE.t0), dur_s=0.0, cat=cat,
        tid=tid, args=args or {}, instant=True))


@contextlib.contextmanager
def span(name: str, *, cat: str = "engine", tid: int = TID_STEPS,
         **args: Any) -> Iterator[None]:
    """``with optrace.span("compile", cat="launch"): ...`` -- records the
    enclosed wall time as one slice (nothing recorded while disabled)."""
    t0 = time.perf_counter()
    try:
        yield
    finally:
        add_span(name, t0, time.perf_counter() - t0, cat=cat, tid=tid,
                 args=args)


def serve_request_spans(req_idx: int, *, t_origin: float, queue_s: float,
                        first_s: float, done_s: float, prompt_len: int,
                        new_tokens: int, slot: int | None = None) -> None:
    """Per-request serve lifecycle: admit -> queue -> prefill ->
    first-token -> decode -> done, one Chrome-trace lane per request.

    Times are the engine's per-call offsets (seconds relative to
    ``t_origin``, an absolute ``perf_counter`` value at ``generate()``
    start): ``queue_s`` = admission offset, ``first_s`` = first sampled
    token, ``done_s`` = completion.
    """
    if not _STATE.enabled:
        return
    tid = TID_REQUEST_BASE + req_idx
    base = {"request": req_idx, "prompt_len": prompt_len,
            "new_tokens": new_tokens}
    if slot is not None:
        base["slot"] = slot
    if queue_s > 0:
        add_span("queue", t_origin, queue_s, cat="serve", tid=tid, args=base)
    add_instant("admit", t_origin + queue_s, cat="serve", tid=tid, args=base)
    add_span("prefill", t_origin + queue_s, max(0.0, first_s - queue_s),
             cat="serve", tid=tid, args=base)
    add_instant("first_token", t_origin + first_s, cat="serve", tid=tid,
                args=base)
    add_span("decode", t_origin + first_s, max(0.0, done_s - first_s),
             cat="serve", tid=tid, args=base)
    add_instant("done", t_origin + done_s, cat="serve", tid=tid, args=base)
