"""Process-global metrics registry: labeled counters, gauges, histograms.

Zero-dependency (stdlib + jax for the tracer guard) and host-side only.
Recording is a plain dict update under the GIL -- no lock is taken on the
hot path; a lock guards only metric *creation*, which happens once per
(name) per process.  Every mutation is tracer-guarded: a record issued
while JAX is tracing (``jax.jit`` staging, ``jax.eval_shape``) or carrying
a ``Tracer`` value is silently dropped, so instrumented code can sit next
to jitted call sites without ever leaking tracers into host state or
double-counting abstract evaluations.

Two exposition formats:

  * :func:`snapshot` -- a plain-JSON dict ``{metric: {"type", "help",
    "values": [{"labels": {...}, "value": ...}]}}`` (histograms carry
    bucket counts, sum, count);
  * :func:`prometheus_text` -- the Prometheus text exposition format
    (``# HELP`` / ``# TYPE`` / ``name{label="x"} value`` lines,
    ``_bucket``/``_sum``/``_count`` series for histograms).

The module-level :data:`REGISTRY` is the process default; engines and the
dispatcher record into it via the convenience constructors below.
"""
from __future__ import annotations

import json
import math
import threading
from typing import Any, Iterable

import jax

METRIC_TYPES = ("counter", "gauge", "histogram")

# generic latency-ish buckets (seconds): 100us .. 60s, plus +Inf implicitly
DEFAULT_BUCKETS = (1e-4, 5e-4, 1e-3, 5e-3, 1e-2, 5e-2, 0.1, 0.5, 1.0, 5.0,
                   10.0, 60.0)


def host_clean(*values: Any) -> bool:
    """True when recording is safe: no JAX trace is being staged and none
    of ``values`` is an abstract ``Tracer``."""
    if not jax.core.trace_state_clean():
        return False
    return not any(isinstance(v, jax.core.Tracer) for v in values)


def _label_key(label_names: tuple[str, ...], labels: dict[str, Any]
               ) -> tuple[str, ...]:
    if set(labels) != set(label_names):
        raise ValueError(
            f"expected labels {label_names}, got {tuple(labels)}")
    return tuple(str(labels[n]) for n in label_names)


class Metric:
    """Base: one named metric with a fixed label schema."""

    type: str = "untyped"

    def __init__(self, name: str, help: str = "",
                 labels: tuple[str, ...] = ()):
        self.name = name
        self.help = help
        self.label_names = tuple(labels)
        self._values: dict[tuple[str, ...], Any] = {}

    def _series(self) -> Iterable[tuple[tuple[str, ...], Any]]:
        return list(self._values.items())

    def labels_dict(self, key: tuple[str, ...]) -> dict[str, str]:
        return dict(zip(self.label_names, key))


class Counter(Metric):
    type = "counter"

    def inc(self, amount: float = 1.0, **labels: Any) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name}: negative increment")
        if not host_clean(amount, *labels.values()):
            return
        key = _label_key(self.label_names, labels)
        self._values[key] = self._values.get(key, 0.0) + float(amount)

    def value(self, **labels: Any) -> float:
        return float(self._values.get(
            _label_key(self.label_names, labels), 0.0))


class Gauge(Metric):
    type = "gauge"

    def set(self, value: float, **labels: Any) -> None:
        if not host_clean(value, *labels.values()):
            return
        self._values[_label_key(self.label_names, labels)] = float(value)

    def add(self, amount: float, **labels: Any) -> None:
        if not host_clean(amount, *labels.values()):
            return
        key = _label_key(self.label_names, labels)
        self._values[key] = self._values.get(key, 0.0) + float(amount)

    def value(self, **labels: Any) -> float:
        return float(self._values.get(
            _label_key(self.label_names, labels), 0.0))


class Histogram(Metric):
    type = "histogram"

    def __init__(self, name: str, help: str = "",
                 labels: tuple[str, ...] = (),
                 buckets: tuple[float, ...] = DEFAULT_BUCKETS):
        super().__init__(name, help, labels)
        bs = tuple(sorted(float(b) for b in buckets))
        if not bs or any(not math.isfinite(b) for b in bs):
            raise ValueError(f"histogram {name}: bad buckets {buckets}")
        self.buckets = bs

    def observe(self, value: float, **labels: Any) -> None:
        if not host_clean(value, *labels.values()):
            return
        key = _label_key(self.label_names, labels)
        st = self._values.get(key)
        if st is None:
            st = self._values[key] = {
                "counts": [0] * (len(self.buckets) + 1),   # +Inf tail
                "sum": 0.0, "count": 0}
        v = float(value)
        idx = len(self.buckets)
        for i, b in enumerate(self.buckets):
            if v <= b:
                idx = i
                break
        st["counts"][idx] += 1
        st["sum"] += v
        st["count"] += 1

    def percentile(self, q: float, **labels: Any) -> float:
        """Approximate percentile from bucket counts (upper bound of the
        bucket containing the q-th observation; +Inf tail reports the last
        finite bound)."""
        st = self._values.get(_label_key(self.label_names, labels))
        if not st or not st["count"]:
            return 0.0
        target = q / 100.0 * st["count"]
        seen = 0
        for i, c in enumerate(st["counts"]):
            seen += c
            if seen >= target and c:
                return self.buckets[min(i, len(self.buckets) - 1)]
        return self.buckets[-1]


class MetricsRegistry:
    """Get-or-create registry; re-registration with a different type or
    label schema is an error (one meaning per name per process)."""

    def __init__(self) -> None:
        self._metrics: dict[str, Metric] = {}
        self._lock = threading.Lock()

    def __len__(self) -> int:
        return len(self._metrics)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def get(self, name: str) -> Metric | None:
        return self._metrics.get(name)

    def _get_or_create(self, cls, name: str, help: str,
                       labels: tuple[str, ...], **kw) -> Metric:
        m = self._metrics.get(name)
        if m is None:
            with self._lock:
                m = self._metrics.get(name)
                if m is None:
                    m = cls(name, help, tuple(labels), **kw)
                    self._metrics[name] = m
        if not isinstance(m, cls) or m.label_names != tuple(labels):
            raise ValueError(
                f"metric {name!r} already registered as {m.type} with "
                f"labels {m.label_names}; asked for {cls.type} with "
                f"{tuple(labels)}")
        return m

    def counter(self, name: str, help: str = "",
                labels: tuple[str, ...] = ()) -> Counter:
        return self._get_or_create(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: tuple[str, ...] = ()) -> Gauge:
        return self._get_or_create(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: tuple[str, ...] = (),
                  buckets: tuple[float, ...] = DEFAULT_BUCKETS) -> Histogram:
        return self._get_or_create(Histogram, name, help, labels,
                                   buckets=buckets)

    def clear(self) -> None:
        with self._lock:
            self._metrics.clear()

    # ------------------------------------------------------------ exposition

    def snapshot(self) -> dict[str, Any]:
        out: dict[str, Any] = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            entry: dict[str, Any] = {"type": m.type, "help": m.help,
                                     "values": []}
            if isinstance(m, Histogram):
                entry["buckets"] = list(m.buckets)
            for key, val in m._series():
                row: dict[str, Any] = {"labels": m.labels_dict(key)}
                if isinstance(m, Histogram):
                    row.update(counts=list(val["counts"]), sum=val["sum"],
                               count=val["count"])
                else:
                    row["value"] = val
                entry["values"].append(row)
            out[name] = entry
        return out

    def write_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1, sort_keys=True)

    def prometheus_text(self) -> str:
        lines: list[str] = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if m.help:
                lines.append(f"# HELP {name} {_escape_help(m.help)}")
            lines.append(f"# TYPE {name} {m.type}")
            for key, val in m._series():
                labels = m.labels_dict(key)
                if isinstance(m, Histogram):
                    cum = 0
                    for i, b in enumerate(m.buckets):
                        cum += val["counts"][i]
                        lines.append(_prom_line(
                            name + "_bucket", {**labels, "le": _fmt(b)},
                            cum))
                    cum += val["counts"][-1]
                    lines.append(_prom_line(
                        name + "_bucket", {**labels, "le": "+Inf"}, cum))
                    lines.append(_prom_line(name + "_sum", labels,
                                            val["sum"]))
                    lines.append(_prom_line(name + "_count", labels,
                                            val["count"]))
                else:
                    lines.append(_prom_line(name, labels, val))
        return "\n".join(lines) + ("\n" if lines else "")


def _fmt(v: float) -> str:
    return repr(v) if v != int(v) else str(int(v))


def _prom_line(name: str, labels: dict[str, str], value: Any) -> str:
    if labels:
        inner = ",".join(
            f'{k}="{_escape(str(v))}"' for k, v in sorted(labels.items()))
        return f"{name}{{{inner}}} {value}"
    return f"{name} {value}"


def _escape(s: str) -> str:
    """Label-value escaping per the exposition-format spec: backslash,
    double-quote, and newline (in that order, so the escapes themselves
    survive)."""
    return s.replace("\\", r"\\").replace('"', r"\"").replace("\n", r"\n")


def _escape_help(s: str) -> str:
    """HELP-text escaping: only backslash and newline (quotes are legal
    verbatim in help text, unlike in label values)."""
    return s.replace("\\", r"\\").replace("\n", r"\n")


# the process-default registry and its convenience constructors
REGISTRY = MetricsRegistry()


def counter(name: str, help: str = "",
            labels: tuple[str, ...] = ()) -> Counter:
    return REGISTRY.counter(name, help, labels)


def gauge(name: str, help: str = "", labels: tuple[str, ...] = ()) -> Gauge:
    return REGISTRY.gauge(name, help, labels)


def histogram(name: str, help: str = "", labels: tuple[str, ...] = (),
              buckets: tuple[float, ...] = DEFAULT_BUCKETS) -> Histogram:
    return REGISTRY.histogram(name, help, labels, buckets)


def snapshot() -> dict[str, Any]:
    return REGISTRY.snapshot()


def prometheus_text() -> str:
    return REGISTRY.prometheus_text()


def clear() -> None:
    REGISTRY.clear()
