"""``python -m repro.obs`` -- run an instrumented workload, emit artifacts.

Two workload pieces, both tiny in ``--smoke`` mode:

  * **ops** -- an *eager* dispatch sampler: a representative sweep of
    ``axon.einsum`` / ``matmul`` / ``conv2d`` / ``depthwise_conv2d`` calls
    (float GeMM/GEMV, zero-gated, quantized int8/int4/fp8, and the
    deliberate XLA-fallback shapes) executed outside ``jax.jit`` so every
    dispatch decision lands in the op-trace ring and the kernel-kind /
    fallback-reason counters.
  * **serve** -- a short continuous-batching ``ServeEngine`` run on a
    paged int8 KV cache with the prefix index on, so the per-request
    lifecycle spans (admit -> queue -> prefill -> first-token -> decode ->
    done), engine-step slices, page-pool occupancy/prefix-hit gauges, and
    mapper cache stats all populate.

Artifacts: ``--trace-out`` (Chrome-trace JSON, load at ui.perfetto.dev),
``--metrics-out`` (registry JSON snapshot), ``--attribution-out``
(measured-vs-modeled attribution per kernel kind -- the op sampler runs
with ``measure_dispatch`` on, so every eager kernel call is wall-timed),
``--prom-out`` (Prometheus text exposition), ``--profile-dir`` (optional
``jax.profiler`` capture: named device scopes nest under the host wall
spans), ``--stream-dir`` (periodic JSONL + Prometheus textfile snapshots
while the workload runs), ``--sample-every`` (record every Nth dispatch
into the ring; counters stay exact).
"""
from __future__ import annotations

import argparse
import json
import sys

import jax
import jax.numpy as jnp

import repro.axon as axon
from repro.obs import (attribution, metrics, optrace, profiler, streaming,
                       trace_export)


def run_op_sampler(*, reps: int = 2) -> None:
    """Eagerly exercise every dispatch route the tracer can observe."""
    key = jax.random.PRNGKey(0)
    ka, kb, kx, kw = jax.random.split(key, 4)
    a = jax.random.normal(ka, (32, 64), jnp.float32)
    b = jax.random.normal(kb, (64, 48), jnp.float32)
    x = jax.random.normal(kx, (1, 8, 8, 16), jnp.float32)
    w = jax.random.normal(kw, (3, 3, 16, 24), jnp.float32)
    dw = jax.random.normal(kw, (3, 3, 16), jnp.float32)
    from repro.quant.qtensor import quantize_weight
    q8 = quantize_weight(b)
    q4 = quantize_weight(jax.random.normal(kb, (64, 64), jnp.float32),
                         fmt="int4")
    qf8 = quantize_weight(b, fmt="fp8")
    q3 = quantize_weight(jax.random.normal(kb, (2, 64, 48), jnp.float32),
                         axis=-1, reduce_axes=(-2,))

    with axon.policy(backend="interpret"):
        for _ in range(reps):
            axon.einsum("mk,kn->mn", a, b)                # gemm
            axon.einsum("k,kn->n", a[0], b)               # gemv (M == 1)
            axon.matmul(a, b)                             # front door alias
            axon.einsum("bmk,bkn->bmn", a[None], b[None])  # shared-batch
            axon.conv2d(x, w, stride=1, padding="SAME")   # im2col conv
            axon.depthwise_conv2d(x, dw, padding=1)       # VPU depthwise
            # deliberate XLA fallbacks: 3 operands / non-float / non-matmul
            axon.einsum("mk,kn,n->m", a, b, jnp.ones((48,)))
            axon.einsum("mk,kn->mn", a.astype(jnp.int32),
                        b.astype(jnp.int32))
            axon.einsum("mn,mn->mn", a[:, :48], a[:, :48] + 1.0)
    with axon.policy(backend="interpret", zero_gate=True):
        axon.einsum("mk,kn->mn", a, b)                    # zero_gate
    with axon.policy(backend="interpret", precision="int8"):
        axon.einsum("mk,kn->mn", a, q8)                   # quant_gemm
        axon.einsum("mk,kn->mn",
                    jax.random.normal(ka, (16, 64)), q4)  # int4_gemm
        axon.einsum("mk,lkn->lmn", a, q3)                 # dequant fallback
    with axon.policy(backend="interpret", precision="fp8"):
        axon.einsum("mk,kn->mn", a, qf8)                  # fp8_gemm


def run_serve_smoke(arch: str, *, n_requests: int = 4) -> dict:
    """Short paged-int8 serve run; returns the engine's last_stats."""
    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.serve.engine import Request, ServeEngine

    cfg = get_config(arch, reduced=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(7)
    reqs = []
    for i in range(n_requests):
        key, sub = jax.random.split(key)
        plen = 8 if i % 2 else 4
        prompt = jax.random.randint(sub, (plen,), 2, cfg.vocab)
        reqs.append(Request(prompt=[int(t) for t in prompt],
                            max_new_tokens=6 if i % 2 else 4))
    page_size = 4
    max_len = -(-(8 + 6 + 1) // page_size) * page_size
    engine = ServeEngine(params, cfg, batch_slots=2, max_len=max_len,
                         prefill_chunk=4, paged=True, page_size=page_size,
                         cache_fmt="int8",
                         pool_pages=4 * (max_len // page_size))
    engine.generate(reqs)
    return engine.last_stats


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="run an instrumented workload and emit telemetry "
                    "artifacts")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI-sized workload (seconds on CPU)")
    ap.add_argument("--workload", choices=("ops", "serve", "all"),
                    default="all")
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--requests", type=int, default=None,
                    help="serve request count (default: 4 smoke, 8 full)")
    ap.add_argument("--ring-size", type=int,
                    default=optrace.DEFAULT_RING_SIZE)
    ap.add_argument("--trace-out", default="trace.json")
    ap.add_argument("--metrics-out", default="metrics.json")
    ap.add_argument("--attribution-out", default="attribution.json",
                    help="measured-vs-modeled attribution report")
    ap.add_argument("--prom-out", default=None,
                    help="also write the Prometheus text exposition here")
    ap.add_argument("--profile-dir", default=None,
                    help="capture a jax.profiler trace into this directory")
    ap.add_argument("--sample-every", type=int, default=1,
                    help="record every Nth dispatch into the op ring "
                         "(side counters stay exact)")
    ap.add_argument("--stream-dir", default=None,
                    help="stream periodic metric snapshots (JSONL + prom "
                         "textfile) into this directory while running")
    ap.add_argument("--stream-interval", type=float,
                    default=streaming.DEFAULT_INTERVAL_S)
    args = ap.parse_args(argv)

    optrace.enable(ring_size=args.ring_size)
    # the op sampler is eager, so dispatch walls are measurable -- that is
    # the measured half of the attribution join
    optrace.configure(sample_every=args.sample_every, measure_dispatch=True)
    if args.profile_dir:
        profiler.start(args.profile_dir)
    if args.stream_dir:
        streaming.start(args.stream_dir, interval_s=args.stream_interval)

    n_req = args.requests or (4 if args.smoke else 8)
    serve_stats = None
    if args.workload in ("ops", "all"):
        with profiler.wall("op_sampler"):
            run_op_sampler(reps=1 if args.smoke else 4)
        print(f"op sampler: {len(optrace.events())} dispatch events "
              f"({optrace.dropped_ops()} dropped)", file=sys.stderr)
    if args.workload in ("serve", "all"):
        with profiler.wall("serve_smoke"):
            serve_stats = run_serve_smoke(args.arch, n_requests=n_req)
        print(f"serve: {serve_stats['generated_tokens']} tokens, "
              f"{serve_stats['tokens_per_s']:.1f} tok/s", file=sys.stderr)

    if args.profile_dir:
        profiler.stop()
    if args.stream_dir:
        streaming.stop()           # final flush: short runs still snapshot

    trace = trace_export.write_chrome_trace(args.trace_out)
    metrics.REGISTRY.write_json(args.metrics_out)
    attr_rep = attribution.write_json(args.attribution_out)
    if args.prom_out:
        with open(args.prom_out, "w") as f:
            f.write(metrics.prometheus_text())

    snap = metrics.snapshot()
    measured = [r["kind"] for r in attr_rep["kinds"]
                if r["measured_wall_s"]]
    summary = {
        "trace_events": len(trace["traceEvents"]),
        "metrics": len(snap),
        "dispatch_kinds": sorted({
            v["labels"]["kind"]
            for v in snap.get("axon_dispatch_total", {}).get("values", [])}),
        "fallback_reasons": sorted({
            v["labels"]["reason"]
            for v in snap.get("axon_fallback_total", {}).get("values", [])}),
        "measured_kinds": sorted(set(measured)),
        "sample_every": optrace.sample_every(),
        "sampled_out_ops": optrace.sampled_out_ops(),
        "trace_out": args.trace_out,
        "metrics_out": args.metrics_out,
        "attribution_out": args.attribution_out,
    }
    if serve_stats is not None and "pool" in serve_stats:
        summary["pool_occupancy"] = serve_stats["pool"]["occupancy"]
    if serve_stats is not None and "attribution" in serve_stats:
        summary["serve_modeled_step_coverage"] = \
            serve_stats["attribution"]["modeled_step_coverage"]
    if args.stream_dir:
        snaps = streaming.read_jsonl(
            f"{args.stream_dir}/{streaming.JSONL_NAME}")
        summary["stream_snapshots"] = len(snaps)
    print(json.dumps(summary, indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
